"""Serving chaos benchmark: load under injected faults, gated on zero
lost requests and bit-identical completed responses.

Three probes over one artifact, written to ``BENCH_chaos.json``:

1. **Artifact integrity** — copy the artifact, flip one byte in a weight
   blob and (separately) a plan JSON (``engine.faults.corrupt_artifact``):
   both loads must raise ``ArtifactCorruptError``; the untouched artifact
   must still load and predict.
2. **Clean load run** — the request stream through a healthy 2-worker
   ``AsyncServer``: the p99 baseline.
3. **Chaos load run** — the same stream with scripted faults armed: a
   worker kill mid-stream (supervisor restarts the slot, requeues its
   batch), repeated predict failures (retry/backoff path), and an
   injected straggler batch (delay).  Gates:

   * **zero lost requests** — every submitted future resolves, with a
     result or a typed ``ServingError``; under a sufficient retry budget
     every one completes with a result;
   * **bit-identical** — each completed response equals sequential
     ``padded_predict`` of the same artifact (retried or not, packed or
     not: bucket-shaped programs make re-execution exact);
   * **bounded p99 inflation** — chaos p99 <= clean p99 + injected delay
     + worst-case retry backoff + scheduling slack (crash recovery costs
     bounded latency, not correctness).

``--smoke`` (CI) shrinks the stream and hard-asserts all three gates.

    PYTHONPATH=../src python serving_chaos.py --smoke \
        --out ../BENCH_chaos.json
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np


def build_requests(session, sizes, n_requests, seed):
    import jax.numpy as jnp

    (name,) = session.input_spec
    tail = session.input_spec[name][1:]
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(
        size=(sizes[i % len(sizes)],) + tail).astype(np.float32))
        for i in range(n_requests)]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifact", default=None,
                    help="saved InferenceSession artifact dir; omitted = "
                         "build a small CNN artifact on the fly")
    ap.add_argument("--model", default="resnet-18")
    ap.add_argument("--image", type=int, default=32)
    ap.add_argument("--bucket", type=int, default=4,
                    help="driver execution bucket (must be specialized)")
    ap.add_argument("--sizes", default="1,2,1",
                    help="request row counts, cycled over the stream")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--retry-budget", type=int, default=3)
    ap.add_argument("--backoff-ms", type=float, default=5.0)
    ap.add_argument("--kill-batch", type=int, default=1,
                    help="global batch sequence the worker kill fires on")
    ap.add_argument("--fail-batches", type=int, default=2,
                    help="number of injected predict failures")
    ap.add_argument("--delay-ms", type=float, default=60.0,
                    help="injected straggler batch delay")
    ap.add_argument("--p99-slack-ms", type=float, default=500.0,
                    help="scheduling slack allowed on top of the modeled "
                         "chaos p99 bound")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small stream + hard gate assertions")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.engine import (ArtifactCorruptError, AsyncServer,
                              DelayBatch, DynamicBatchPolicy, FailBatch,
                              FaultInjector, InferenceSession, KillWorker,
                              RetryPolicy, ServingError, corrupt_artifact,
                              padded_predict)
    from repro.engine import compile as compile_session

    sizes = [int(s) for s in args.sizes.split(",")]
    if args.smoke:
        args.requests = min(args.requests, 24)

    tmp = tempfile.TemporaryDirectory(prefix="neocpu_chaos_")
    if args.artifact is None:
        art = Path(tmp.name) / "artifact"
        sess = compile_session(args.model, (1, 3, args.image, args.image))
        for b in sorted({1, args.bucket}):
            sess.specialize(b)
        sess.save(art)
    else:
        art = Path(args.artifact)

    # -- probe 1: artifact integrity ----------------------------------------
    integrity = {}
    for kind in ("weights", "plan"):
        victim = Path(tmp.name) / f"corrupt_{kind}"
        shutil.copytree(art, victim)
        flipped = corrupt_artifact(victim, kind=kind)
        try:
            InferenceSession.load(victim)
            integrity[kind] = "LOADED (gate fails: corruption accepted)"
        except ArtifactCorruptError as e:
            integrity[kind] = f"rejected: {type(e).__name__}"
        print(f"integrity[{kind}]: flipped {flipped.name} -> "
              f"{integrity[kind]}")
    integrity_ok = all(v.startswith("rejected") for v in integrity.values())

    session = InferenceSession.load(art)     # the clean artifact loads
    if args.bucket not in session.batch_sizes:
        raise SystemExit(f"--bucket {args.bucket} not specialized in "
                         f"{art} (has {session.batch_sizes})")

    requests = build_requests(session, sizes, args.requests, args.seed)
    refs = [np.asarray(padded_predict(session, x, bucket=args.bucket))
            for x in requests]
    for b in session.batch_sizes:            # pre-warm every bucket: JIT
        jax.block_until_ready(session.specialize(b).predict(jnp.zeros(
            (b,) + session.input_spec[next(iter(session.input_spec))][1:],
            jnp.float32)))

    policy = DynamicBatchPolicy(max_batch=args.bucket, max_wait_ms=2.0,
                                fixed_bucket=args.bucket)
    retry = RetryPolicy(budget=args.retry_budget,
                        backoff_ms=args.backoff_ms)

    def run(faults=None):
        srv = AsyncServer(session, policy, max_queue=len(requests),
                          workers=args.workers, retry=retry, faults=faults)
        t0 = time.perf_counter()
        futs = [srv.submit(x) for x in requests]
        outs = []
        for f in futs:
            try:
                outs.append(np.asarray(f.result(timeout=120)))
            except ServingError as e:
                outs.append(e)               # typed failure, not lost
        wall = time.perf_counter() - t0
        srv.close()
        return outs, srv, wall

    # -- probe 2: clean baseline --------------------------------------------
    clean_outs, clean_srv, clean_wall = run()
    clean_p99 = clean_srv.stats.percentile_ms(99)

    # -- probe 3: chaos run -------------------------------------------------
    injector = FaultInjector(
        KillWorker(on_batch=args.kill_batch),
        FailBatch(times=args.fail_batches),
        DelayBatch(on_batch=max(args.kill_batch + 2, 3),
                   delay_ms=args.delay_ms))
    chaos_outs, chaos_srv, chaos_wall = run(faults=injector)
    chaos_p99 = chaos_srv.stats.percentile_ms(99)

    n_lost = sum(1 for o in chaos_outs
                 if not isinstance(o, (np.ndarray, ServingError)))
    n_typed_failures = sum(isinstance(o, ServingError)
                           for o in chaos_outs)
    completed_identical = all(
        o.shape == r.shape and o.tobytes() == r.tobytes()
        for o, r in zip(chaos_outs, refs) if isinstance(o, np.ndarray))
    clean_identical = all(
        o.shape == r.shape and o.tobytes() == r.tobytes()
        for o, r in zip(clean_outs, refs) if isinstance(o, np.ndarray))
    # worst-case per-request chaos overhead: the injected delay, the full
    # backoff ladder, and scheduling slack on top of the clean p99
    backoff_total_ms = sum(
        retry.backoff_s(a) * 1e3 for a in range(1, retry.budget + 1))
    p99_bound_ms = clean_p99 + args.delay_ms + backoff_total_ms \
        + args.p99_slack_ms
    p99_ok = chaos_p99 <= p99_bound_ms

    record = {
        "benchmark": "serving_chaos",
        "artifact": str(art),
        "model": session.model_name,
        "buckets": session.batch_sizes,
        "bucket": args.bucket,
        "n_requests": args.requests,
        "request_sizes": sizes,
        "workers": args.workers,
        "retry_budget": args.retry_budget,
        "backoff_ms": args.backoff_ms,
        "faults_armed": {"kill_batch": args.kill_batch,
                         "fail_batches": args.fail_batches,
                         "delay_ms": args.delay_ms},
        "faults_fired": injector.fired,
        "integrity_probe": integrity,
        "clean": {"wall_s": round(clean_wall, 3),
                  "p99_ms": round(clean_p99, 2),
                  "stats": clean_srv.stats.to_json()},
        "chaos": {"wall_s": round(chaos_wall, 3),
                  "p99_ms": round(chaos_p99, 2),
                  "stats": chaos_srv.stats.to_json(),
                  "health": chaos_srv.health()},
        "gates": {
            "integrity_corruption_rejected": integrity_ok,
            "zero_lost_requests": n_lost == 0,
            "n_typed_failures": n_typed_failures,
            "completed_bit_identical": bool(completed_identical
                                            and clean_identical),
            "p99_bound_ms": round(p99_bound_ms, 2),
            "p99_within_bound": bool(p99_ok),
        },
    }
    Path(args.out).write_text(json.dumps(record, indent=2))
    cs = chaos_srv.stats
    print(f"clean: {args.requests} requests in {clean_wall:.2f} s, "
          f"p99={clean_p99:.1f} ms")
    print(f"chaos: {args.requests} requests in {chaos_wall:.2f} s, "
          f"p99={chaos_p99:.1f} ms (bound {p99_bound_ms:.1f}), "
          f"fired={injector.fired_kinds()}")
    print(f"  crashes={cs.n_worker_crashes} restarts={cs.n_worker_restarts}"
          f" retried={cs.n_retried} exhausted={cs.n_retries_exhausted} "
          f"failed={cs.n_failed} completed={cs.n_completed}")
    print(f"  lost={n_lost} typed_failures={n_typed_failures} "
          f"bit_identical={completed_identical} integrity={integrity}")
    print(f"wrote {args.out}")

    if args.smoke:
        assert integrity_ok, f"corruption probe accepted: {integrity}"
        assert n_lost == 0, f"{n_lost} requests lost (unresolved futures)"
        assert completed_identical and clean_identical, \
            "completed responses drifted from sequential padded_predict"
        assert injector.fired_kinds(), "no armed fault actually fired"
        assert cs.n_worker_crashes >= 1 or cs.n_retried >= 1, \
            "chaos run exercised no recovery path"
        assert n_typed_failures == 0, \
            (f"{n_typed_failures} requests failed typed — retry budget "
             f"{args.retry_budget} should absorb the scripted faults")
        assert p99_ok, (f"chaos p99 {chaos_p99:.1f} ms exceeds bound "
                        f"{p99_bound_ms:.1f} ms")
        print("smoke assertions passed (corruption rejected, zero lost, "
              "bit-identical, recovery exercised, p99 bounded)")


if __name__ == "__main__":
    main()
