"""Benchmark harness entry: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--measured]

Sections:
  table2   — overall latency (paper Table 2): measured CPU + predicted v5e
  table3   — optimization-implication ladder (paper Table 3)
  figure4  — parallel-scaling efficiency (paper Figure 4, TPU analogue)
  roofline — per-(arch x shape) roofline terms from the dry-run artifacts

Output: ``name,us_per_call,derived`` CSV per section.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all 15 table-2 models (slow on 1 core)")
    ap.add_argument("--measured", action="store_true",
                    help="also run the measured table-3 ladder")
    ap.add_argument("--skip-table2", action="store_true")
    args = ap.parse_args()
    t0 = time.time()

    from benchmarks import (figure4_scaling, roofline_report,
                            table2_overall, table3_breakdown)

    print("== roofline (from dry-run artifacts) ==", flush=True)
    roofline_report.main(["--mesh", "16x16"])

    print("\n== figure4: scaling ==", flush=True)
    figure4_scaling.main([])

    print("\n== table3: ablation ladder (predicted v5e) ==", flush=True)
    table3_breakdown.main([])

    if args.measured:
        print("\n== table3: measured ladder (guided search on host CPU) ==",
              flush=True)
        table3_breakdown.main(["--measured"])

    if not args.skip_table2:
        print("\n== table2: overall latency ==", flush=True)
        table2_overall.main(["--full"] if args.full else [])

    print(f"\n# benchmarks completed in {time.time() - t0:.0f}s",
          flush=True)


if __name__ == "__main__":
    main()
