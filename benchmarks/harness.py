"""Shared measurement harness for every BENCH_* artifact.

This host is a shared 2-vCPU box with pronounced *phase noise*: multi-second
stretches where a neighbor tenant (or the first touch of a freshly compiled
executable) inflates wall-clock by 2-5x, then releases.  Two defenses, used
together by every benchmark:

* **warmup-phase detection** — before recording anything, run alternating
  rounds until each variant's rolling-window median stabilizes (successive
  windows within ``tol`` of each other).  This absorbs both compile/first-
  touch effects and a noisy phase at benchmark start, instead of guessing a
  fixed warmup count.
* **interleaved paired A/B sampling** — all variants are timed round-robin
  within each round, so a slow phase in the middle of the run hits every
  variant equally and the reported *medians* stay comparable.

``measure_paired`` is the one entry point; ``Timing`` is what it returns
per variant.  ``benchmarks/fusion_ablation.py`` and
``benchmarks/template_variants.py`` both ride on it, so ``BENCH_fusion.json``
and ``BENCH_variants.json`` share one methodology.

CPU pinning lives in ``repro.launch.cpu.maybe_pin`` (one implementation
shared with the serving workers); ``maybe_pin`` is re-exported here for
the benchmarks.  Set ``BENCH_PIN=1`` to restrict the process to one core,
so the scheduler stops migrating the benchmark across cores mid-phase on
multi-tenant hosts.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, List, Sequence

import jax

from repro.launch.cpu import maybe_pin   # noqa: F401 — benchmark re-export


def _time_one_ms(fn: Callable) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return (time.perf_counter() - t0) * 1e3


@dataclasses.dataclass
class Timing:
    """Per-variant result of one paired measurement."""

    median_ms: float
    min_ms: float
    mean_ms: float
    n_samples: int
    warmup_rounds: int       # rounds consumed by phase detection
    samples_ms: List[float] = dataclasses.field(default_factory=list)

    def to_json(self, with_samples: bool = False) -> dict:
        out = {"median_ms": round(self.median_ms, 3),
               "min_ms": round(self.min_ms, 3),
               "mean_ms": round(self.mean_ms, 3),
               "n_samples": self.n_samples,
               "warmup_rounds": self.warmup_rounds}
        if with_samples:
            out["samples_ms"] = [round(s, 3) for s in self.samples_ms]
        return out


def warmed_up(history: Sequence[Sequence[float]], window: int,
              tol: float) -> bool:
    """True when every variant's last-``window`` median is within ``tol``
    (relative) of the preceding window's median — i.e. the run has left the
    warmup/noise phase and entered a stable one."""
    for h in history:
        if len(h) < 2 * window:
            return False
        cur = statistics.median(h[-window:])
        prev = statistics.median(h[-2 * window:-window])
        if abs(cur - prev) > tol * max(prev, 1e-9):
            return False
    return True


def measure_paired(fns: Sequence[Callable], repeats: int = 30,
                   window: int = 3, tol: float = 0.10,
                   max_warmup_rounds: int = 12) -> List[Timing]:
    """Interleaved paired medians with warmup-phase detection.

    ``fns`` are zero-arg callables returning a jax value (blocked on via
    ``jax.block_until_ready``).  Each round times every fn once, in order;
    recording starts only once ``warmed_up`` says the phase is stable (or
    ``max_warmup_rounds`` is exhausted — noisy hosts must not stall the
    benchmark forever).
    """
    maybe_pin()                         # no-op unless BENCH_PIN=1
    for f in fns:                       # compile + first touch
        jax.block_until_ready(f())
    history: List[List[float]] = [[] for _ in fns]
    rounds = 0
    while rounds < max_warmup_rounds:
        for i, f in enumerate(fns):
            history[i].append(_time_one_ms(f))
        rounds += 1
        if warmed_up(history, window, tol):
            break
    samples: List[List[float]] = [[] for _ in fns]
    for _ in range(repeats):
        for i, f in enumerate(fns):
            samples[i].append(_time_one_ms(f))
    return [Timing(median_ms=statistics.median(s), min_ms=min(s),
                   mean_ms=statistics.fmean(s), n_samples=len(s),
                   warmup_rounds=rounds, samples_ms=s)
            for s in samples]
