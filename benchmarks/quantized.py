"""Int8 (W8 weight-only) vs fp32: agreement, artifact size, and speed.

Compiles the same zoo network twice — fp32 and ``dtype="int8"`` (per-
output-channel symmetric weight quantization, dequant folded into the
fused epilogue) — and reports into ``BENCH_quantized.json``:

* **top-1 agreement** over random calibration inputs (the quantization
  acceptance gate: >= 99% or this benchmark exits non-zero),
* **artifact weight payload** — int8 conv blobs must come in at <= 55%
  of the fp32 artifact (they land near 28%: conv weights are int8, the
  dense/BN tensors stay fp32),
* **paired speed** — interleaved A/B medians via ``harness.measure_paired``
  (phase-noise-resistant on this shared host),
* **mixed precision** — the per-conv schedule dtypes the search actually
  picked (from the artifact's ``quantized.json``: stage-1 convs stay
  fp32, the weight-heavier stages go int8) plus analytical-vs-measured
  dtype verdicts on one weight-heavy workload.

    PYTHONPATH=src python benchmarks/quantized.py --smoke --out .
"""
from __future__ import annotations

import argparse
import json
import shutil
import statistics
import sys
import tempfile
from pathlib import Path

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent))
from harness import Timing, measure_paired            # noqa: E402

from repro.core.local_search import guided_local_search  # noqa: E402
from repro.core.schedule import ConvWorkload             # noqa: E402
from repro.engine import compile as compile_session      # noqa: E402
from repro.models.cnn import build                       # noqa: E402

MIN_AGREEMENT = 0.99
MAX_PAYLOAD_RATIO = 0.55


def conv_weight_bytes(art: Path) -> int:
    """Blocked conv weight payload of a saved artifact (the tensors the
    quantizer touches; dense/BN stay fp32 in both artifacts)."""
    total = 0
    for f in sorted((art / "weights").rglob("*.npy")):
        arr = np.load(f)
        if arr.ndim >= 5:
            total += arr.nbytes
    return total


def top1_agreement(f32, i8, shape, n_inputs: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    agree = 0
    max_rel = 0.0
    for _ in range(n_inputs):
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        yf = np.asarray(f32.predict(x))
        yq = np.asarray(i8.predict(x))
        agree += int(np.array_equal(np.argmax(yf, 1), np.argmax(yq, 1)))
        denom = float(np.max(np.abs(yf))) or 1.0
        max_rel = max(max_rel, float(np.max(np.abs(yf - yq))) / denom)
    return {"n_inputs": n_inputs, "agreement": agree / n_inputs,
            "max_rel_logit_diff": round(max_rel, 6)}


def measured_mixed_demo(smoke: bool) -> dict:
    """The dtype axis through both searches, on one weight-heavy conv.

    The *analytical* ranking prices int8's 4x lighter weight traffic and
    picks it on memory-bound workloads — that is where the mixed plan in
    the artifact comes from.  The *guided wall-clock* search then prices
    what the model cannot see: on this XLA:CPU the int8 weight upcast
    materializes a full fp32 copy per call, so measured cost usually
    keeps fp32 unless int8 lands within the noise floor (where the
    analytical tiebreak prefers its lighter traffic).  Both verdicts are
    recorded — the disagreement is the finding."""
    from repro.core.local_search import local_search, roofline_runner
    wl = ConvWorkload(batch=1, in_channels=256, out_channels=256,
                      height=14, width=14, kh=3, kw=3, pad=1,
                      fused_bn=True, fused_relu=True, quantize=True)
    analytical = local_search(wl, roofline_runner)
    res = guided_local_search(wl, top_k=2 if smoke else 4, per_variant=1,
                              repeats=2 if smoke else 3)
    ranked = [{"variant": r.schedule.resolved_variant(),
               "dtype": r.schedule.dtype,
               "ic_bn": r.schedule.ic_bn, "oc_bn": r.schedule.oc_bn,
               "cost_ms": round(r.cost_s * 1e3, 3)}
              for r in res.ranked]
    return {"workload": "n1_c256_k256_h14_w14_r3s3",
            "analytical_winner_dtype": analytical.best.dtype,
            "measured": ranked, "measured_winner_dtype": res.best.dtype}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="resnet-18")
    ap.add_argument("--image", type=int, default=80,
                    help="reduced input resolution (full 224 compiles "
                         "for minutes on this 2-vCPU host).  80 keeps the "
                         "global-pool window large enough (3x3 per stage) "
                         "that W8 logit noise averages out: top-1 flip "
                         "rate vs fp32 is ~0.6% here vs ~3% at 56-64, "
                         "where the 2x2 pool leaves single-position noise "
                         "in the logits")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--inputs", type=int, default=100,
                    help="random calibration inputs for the agreement gate")
    ap.add_argument("--repeats", type=int, default=30)
    ap.add_argument("--smoke", action="store_true",
                    help="CI budget: fewer inputs/repeats, smaller search")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_quantized.json")
    args = ap.parse_args()
    if args.smoke:
        args.inputs = min(args.inputs, 8)
        args.repeats = min(args.repeats, 10)

    g, shapes = build(args.model, batch=args.batch, image=args.image)
    g2, _ = build(args.model, batch=args.batch, image=args.image)
    (shape,) = shapes.values()
    print(f"compiling {args.model} @ {shape} fp32 ...", flush=True)
    f32 = compile_session(g, shapes, seed=0)
    print("compiling int8 twin ...", flush=True)
    i8 = compile_session(g2, shapes, seed=0, dtype="int8")

    agreement = top1_agreement(f32, i8, shape, args.inputs)
    print(f"top-1 agreement {agreement['agreement']:.3f} over "
          f"{args.inputs} inputs "
          f"(max rel logit diff {agreement['max_rel_logit_diff']:.2e})",
          flush=True)

    tmp = Path(tempfile.mkdtemp(prefix="bench_quantized_"))
    try:
        a32 = f32.save(tmp / "fp32")
        a8 = i8.save(tmp / "int8")
        b32, b8 = conv_weight_bytes(a32), conv_weight_bytes(a8)
        ratio = b8 / b32
        dtypes = json.loads((a8 / "quantized.json").read_text())[
            "schedule_dtypes"][str(args.batch)]
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    n_i8 = sum(d == "int8" for d in dtypes.values())
    print(f"conv weight payload: int8 {b8 / 1e6:.2f} MB vs "
          f"fp32 {b32 / 1e6:.2f} MB ({ratio:.1%}); "
          f"plan: {n_i8}/{len(dtypes)} convs int8", flush=True)

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    t32, t8 = measure_paired([lambda: f32.predict(x),
                              lambda: i8.predict(x)],
                             repeats=args.repeats)
    speedup = t32.median_ms / t8.median_ms
    print(f"latency: fp32 {t32.median_ms:.2f} ms, int8 {t8.median_ms:.2f} "
          f"ms (paired-median speedup {speedup:.3f}x)", flush=True)

    mixed = measured_mixed_demo(args.smoke)
    print(f"search on {mixed['workload']}: analytical winner "
          f"{mixed['analytical_winner_dtype']}, measured winner "
          f"{mixed['measured_winner_dtype']}", flush=True)

    report = {
        "model": args.model, "image": args.image, "batch": args.batch,
        "smoke": args.smoke,
        "agreement": agreement,
        "artifact": {"conv_weight_bytes_fp32": b32,
                     "conv_weight_bytes_int8": b8,
                     "payload_ratio": round(ratio, 4),
                     "schedule_dtypes": dtypes,
                     "n_int8_convs": n_i8, "n_convs": len(dtypes)},
        "latency": {"fp32": t32.to_json(), "int8": t8.to_json(),
                    "speedup": round(speedup, 4)},
        "measured_mixed_precision": mixed,
    }
    out = Path(args.out) / "BENCH_quantized.json"
    out.write_text(json.dumps(report, indent=1))
    print(f"wrote {out}", flush=True)

    failures = []
    if agreement["agreement"] < MIN_AGREEMENT:
        failures.append(f"top-1 agreement {agreement['agreement']:.3f} "
                        f"< {MIN_AGREEMENT}")
    if ratio > MAX_PAYLOAD_RATIO:
        failures.append(f"int8 weight payload {ratio:.1%} of fp32 "
                        f"(> {MAX_PAYLOAD_RATIO:.0%})")
    if n_i8 == 0:
        failures.append("search selected int8 for zero convs")
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr, flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
