"""Shared benchmark helpers: timing, CSV output, model prep.

``prepare`` rides on the compile()/Pipeline API (``repro.engine.compile``
with ``Pipeline.preset(mode)``), so every table/figure benchmark exercises
the same code path a served session does.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.local_search import ScheduleDatabase
from repro.core.pipeline import Pipeline
from repro.engine import compile as compile_session
from repro.models.cnn import build

_DB = ScheduleDatabase()    # shared across benchmarks in one process


def time_fn(fn: Callable, repeats: int = 3) -> float:
    """Seconds per call after one warmup (also the compile trigger)."""
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / repeats


def prepare(name: str, mode: str, batch: int = 1, db=None, **preset_kw):
    """(session, input array, plan) for one zoo network under
    ``Pipeline.preset(mode)``; the session predicts like the old compiled
    model and the plan carries the predicted ladder terms."""
    g, shapes = build(name, batch=batch)
    # `db if ... else`, NOT `db or`: an empty caller database (e.g.
    # table3's GuidedDB before its first search) is falsy but must be used
    session = compile_session(g, shapes,
                              pipeline=Pipeline.preset(mode, **preset_kw),
                              db=db if db is not None else _DB)
    p = session.plan_for(batch)
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=shapes["data"]).astype(np.float32))
    return session, x, p


def emit(rows: List[Tuple]) -> None:
    """CSV per harness convention: name,us_per_call,derived."""
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
