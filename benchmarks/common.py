"""Shared benchmark helpers: timing, CSV output, model prep."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.local_search import ScheduleDatabase
from repro.core.planner import plan
from repro.engine import compile_model
from repro.models.cnn import build
from repro.nn.init import init_params

_DB = ScheduleDatabase()    # shared across benchmarks in one process


def time_fn(fn: Callable, repeats: int = 3) -> float:
    """Seconds per call after one warmup (also the compile trigger)."""
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / repeats


def prepare(name: str, mode: str, batch: int = 1, db=None, **plan_kw):
    """(compiled model, input array, plan) for one zoo network."""
    g, shapes = build(name, batch=batch)
    params = init_params(g, shapes, seed=0)
    p = plan(g, shapes, mode=mode, db=db or _DB, **plan_kw)
    m = compile_model(p, params)
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=shapes["data"]).astype(np.float32))
    return m, x, p


def emit(rows: List[Tuple]) -> None:
    """CSV per harness convention: name,us_per_call,derived."""
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
