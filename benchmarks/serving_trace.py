"""Trace-replay serving benchmark: learned bucket sets and multi-tenant
hosting under realistic traffic shapes, written to ``BENCH_trace.json``.

Two phases over artifacts built (or passed) on the fly:

1. **Single tenant, learned buckets** — replay a deterministic
   heavy-tail trace (``engine.traffic.synth_trace``) through an
   artifact saved with the hand-picked ``{1, 8}`` bucket set, measure
   the arrival-size histogram, then:

   * **solver gate** — ``solve_buckets`` on the measured histogram must
     have expected padded waste <= the hand-picked set's on the same
     distribution;
   * re-save the artifact with ``buckets="auto"`` (the learned set),
     reload it, and replay the same trace through it pinned to one
     fixed bucket — every completed response must be **bit-identical**
     to sequential ``padded_predict`` through the same (bucket,
     device-count) program, and p99 must stay within the modeled bound
     (baseline p99 + flush window + scheduling slack).

2. **Two-tenant fleet under memory pressure** — load the learned
   artifact twice (source-packed, so specializations are evictable)
   behind one ``FleetServer`` whose memory budget is set *below* the
   two tenants' combined resident footprint, then replay a bursty
   two-tenant trace routed by tenant name.  Gates:

   * **evictions happened** — the budget forced at least one LRU
     release (``fleet.n_evictions >= 1``);
   * **zero lost requests** — every submitted future resolves with a
     result or a typed ``ServingError`` (eviction trades latency, never
     availability: evicted buckets re-specialize on demand);
   * **bit-identical** — completed responses match sequential
     ``padded_predict`` per tenant;
   * **bounded p99** — each tenant's p99 within the phase-1 baseline
     plus flush window plus slack.

``--smoke`` (CI) shrinks both traces and hard-asserts every gate.

    PYTHONPATH=../src python serving_trace.py --smoke \
        --out ../BENCH_trace.json
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import numpy as np


def build_inputs(trace, tail, seed):
    """One deterministic input tensor per trace request."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(size=(r.rows,) + tail)
                        .astype(np.float32)) for r in trace]


def prewarm(session):
    import jax
    import jax.numpy as jnp

    (name,) = session.input_spec
    tail = session.input_spec[name][1:]
    for b in session.batch_sizes:
        jax.block_until_ready(session.specialize(b).predict(
            jnp.zeros((b,) + tail, jnp.float32)))


def replay(submit, trace, xs, time_scale):
    """Paced replay honouring the trace's arrival times (compressed by
    ``time_scale``); returns (futures, wall_s)."""
    t0 = time.perf_counter()
    futs = []
    for req, x in zip(trace, xs):
        target = req.t * time_scale
        lag = target - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        futs.append(submit(req, x))
    wall = time.perf_counter() - t0
    return futs, wall


def settle(futs, ServingError, timeout=120):
    """Resolve every future: ndarray, typed ServingError, or lost."""
    outs = []
    for f in futs:
        try:
            outs.append(np.asarray(f.result(timeout=timeout)))
        except ServingError as e:
            outs.append(e)
    return outs


def check_identical(outs, refs):
    return all(o.shape == r.shape and o.tobytes() == r.tobytes()
               for o, r in zip(outs, refs) if isinstance(o, np.ndarray))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifact", default=None,
                    help="saved artifact dir with hand-picked buckets; "
                         "omitted = build a small CNN artifact on the fly")
    ap.add_argument("--model", default="resnet-18")
    ap.add_argument("--image", type=int, default=32)
    ap.add_argument("--trace", default="bursty",
                    help="phase-2 trace kind (phase 1 always replays "
                         "heavytail — the distribution the solver gate "
                         "is about)")
    ap.add_argument("--requests", type=int, default=96,
                    help="requests per phase")
    ap.add_argument("--mean-rate", type=float, default=200.0,
                    help="trace arrival rate (req/s) before scaling")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="replay pacing multiplier (<1 compresses)")
    ap.add_argument("--max-rows", type=int, default=8)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--p99-slack-ms", type=float, default=500.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_trace.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: short traces + hard gate assertions")
    args = ap.parse_args()

    from repro.engine import (AsyncServer, DynamicBatchPolicy, FleetServer,
                              InferenceSession, ServingError,
                              expected_padded_waste, padded_predict,
                              solve_buckets, synth_trace)
    from repro.engine import compile as compile_session

    if args.smoke:
        args.requests = min(args.requests, 48)

    hand_buckets = [1, args.max_rows]
    tmp = tempfile.TemporaryDirectory(prefix="neocpu_trace_")
    if args.artifact is None:
        art = Path(tmp.name) / "artifact_hand"
        sess = compile_session(args.model, (1, 3, args.image, args.image))
        for b in hand_buckets:
            sess.specialize(b)
        sess.save(art, include_source=True)
    else:
        art = Path(args.artifact)

    session = InferenceSession.load(art)
    (in_name,) = session.input_spec
    tail = session.input_spec[in_name][1:]
    hand_buckets = sorted(session.batch_sizes)

    # -- phase 1: heavy-tail trace through the hand-picked set ---------------
    trace1 = synth_trace("heavytail", n=args.requests, seed=args.seed,
                         mean_rate=args.mean_rate, max_rows=args.max_rows)
    xs1 = build_inputs(trace1, tail, args.seed)
    prewarm(session)

    policy = DynamicBatchPolicy(max_batch=args.max_rows,
                                max_wait_ms=args.max_wait_ms,
                                fixed_bucket=max(hand_buckets))
    srv = AsyncServer(session, policy, max_queue=args.requests,
                      workers=args.workers)
    futs, wall_hand = replay(lambda r, x: srv.submit(x), trace1, xs1,
                             args.time_scale)
    outs_hand = settle(futs, ServingError)
    stats_hand = srv.stats
    srv.close()
    hand_p99 = stats_hand.percentile_ms(99)

    # solver gate on the histogram the replay actually measured — the
    # same call save(buckets="auto") makes, so learned == artifact set
    hist = {s: c for s, c in stats_hand.arrival_hist.counts().items()}
    learned = solve_buckets(hist, devices=session.devices)
    waste_hand = expected_padded_waste(hist, hand_buckets)
    waste_learned = expected_padded_waste(hist, learned)
    solver_ok = waste_learned <= waste_hand
    print(f"phase1: measured sizes {hist}")
    print(f"phase1: learned buckets {learned} waste={waste_learned} vs "
          f"hand-picked {hand_buckets} waste={waste_hand}")

    # re-save with the learned set and serve the same trace through it
    art_auto = Path(tmp.name) / "artifact_auto"
    session.save(art_auto, buckets="auto",
                 traffic=stats_hand.arrival_hist)
    auto_sess = InferenceSession.load(art_auto)
    assert sorted(auto_sess.batch_sizes) == sorted(learned), \
        (auto_sess.batch_sizes, learned)
    prewarm(auto_sess)
    pin = max(auto_sess.batch_sizes)
    refs1 = [np.asarray(padded_predict(auto_sess, x, bucket=pin))
             for x in xs1]
    srv = AsyncServer(auto_sess,
                      DynamicBatchPolicy(max_batch=pin,
                                         max_wait_ms=args.max_wait_ms,
                                         fixed_bucket=pin),
                      max_queue=args.requests, workers=args.workers)
    futs, wall_auto = replay(lambda r, x: srv.submit(x), trace1, xs1,
                             args.time_scale)
    outs_auto = settle(futs, ServingError)
    stats_auto = srv.stats
    srv.close()
    auto_p99 = stats_auto.percentile_ms(99)
    p99_bound = hand_p99 + args.max_wait_ms + args.p99_slack_ms
    auto_lost = sum(1 for o in outs_auto
                    if not isinstance(o, (np.ndarray, ServingError)))
    auto_identical = check_identical(outs_auto, refs1)
    print(f"phase1: hand p99={hand_p99:.1f} ms, auto p99={auto_p99:.1f} ms "
          f"(bound {p99_bound:.1f}), identical={auto_identical}")

    # -- phase 2: two-tenant fleet under memory pressure ---------------------
    tenants = ("alpha", "beta")
    trace2 = synth_trace(args.trace, n=args.requests, seed=args.seed + 1,
                         mean_rate=args.mean_rate, max_rows=args.max_rows,
                         tenants=tenants)
    xs2 = build_inputs(trace2, tail, args.seed + 1)
    sess_a = InferenceSession.load(art_auto)
    sess_b = InferenceSession.load(art_auto)
    prewarm(sess_a)
    prewarm(sess_b)
    refs2 = [np.asarray(padded_predict(
        sess_a if r.tenant == "alpha" else sess_b, x, bucket=pin))
        for r, x in zip(trace2, xs2)]
    resident = (sum(sess_a.memory_bytes().values())
                + sum(sess_b.memory_bytes().values()))
    budget = resident - min(sess_a.memory_bytes().values()) // 2

    fleet = FleetServer(memory_budget_bytes=budget,
                        max_queue=args.requests, workers=args.workers)
    tenant_policy = DynamicBatchPolicy(max_batch=pin,
                                       max_wait_ms=args.max_wait_ms,
                                       fixed_bucket=pin)
    fleet.add_model("alpha", sess_a, policy=tenant_policy)
    fleet.add_model("beta", sess_b, policy=tenant_policy)
    futs, wall_fleet = replay(
        lambda r, x: fleet.submit(r.tenant, x, priority=r.priority),
        trace2, xs2, args.time_scale)
    outs_fleet = settle(futs, ServingError)
    fleet_stats = fleet.stats()
    n_evictions = fleet.n_evictions
    fleet_health = fleet.health()
    fleet.close()

    fleet_lost = sum(1 for o in outs_fleet
                     if not isinstance(o, (np.ndarray, ServingError)))
    fleet_typed = sum(isinstance(o, ServingError) for o in outs_fleet)
    fleet_identical = check_identical(outs_fleet, refs2)
    fleet_p99 = {name: st.percentile_ms(99)
                 for name, st in fleet_stats.items()}
    fleet_p99_ok = all(p <= p99_bound for p in fleet_p99.values()
                       if np.isfinite(p))
    print(f"phase2 ({args.trace}): evictions={n_evictions} lost="
          f"{fleet_lost} typed={fleet_typed} identical={fleet_identical}")
    print(f"phase2: p99 per tenant "
          f"{ {k: round(v, 1) for k, v in fleet_p99.items()} } "
          f"(bound {p99_bound:.1f})")

    record = {
        "benchmark": "serving_trace",
        "model": session.model_name,
        "n_requests": args.requests,
        "max_rows": args.max_rows,
        "workers": args.workers,
        "time_scale": args.time_scale,
        "phase1": {
            "trace": "heavytail",
            "measured_hist": {str(k): v for k, v in sorted(hist.items())},
            "hand_buckets": hand_buckets,
            "learned_buckets": learned,
            "waste_hand": waste_hand,
            "waste_learned": waste_learned,
            "hand": {"wall_s": round(wall_hand, 3),
                     "p99_ms": round(hand_p99, 2),
                     "stats": stats_hand.to_json()},
            "auto": {"wall_s": round(wall_auto, 3),
                     "p99_ms": round(auto_p99, 2),
                     "stats": stats_auto.to_json()},
        },
        "phase2": {
            "trace": args.trace,
            "tenants": list(tenants),
            "memory_budget_bytes": budget,
            "n_evictions": n_evictions,
            "wall_s": round(wall_fleet, 3),
            "p99_ms": {k: round(v, 2) for k, v in fleet_p99.items()},
            "health": fleet_health,
        },
        "gates": {
            "solver_waste_not_worse": bool(solver_ok),
            "auto_zero_lost": auto_lost == 0,
            "auto_bit_identical": bool(auto_identical),
            "p99_bound_ms": round(p99_bound, 2),
            "auto_p99_within_bound": bool(auto_p99 <= p99_bound),
            "fleet_evictions": n_evictions,
            "fleet_zero_lost": fleet_lost == 0,
            "fleet_n_typed_failures": fleet_typed,
            "fleet_bit_identical": bool(fleet_identical),
            "fleet_p99_within_bound": bool(fleet_p99_ok),
        },
    }
    Path(args.out).write_text(json.dumps(record, indent=2))
    print(f"wrote {args.out}")

    if args.smoke:
        assert solver_ok, (f"learned waste {waste_learned} > hand-picked "
                           f"{waste_hand} on {hist}")
        assert auto_lost == 0 and fleet_lost == 0, \
            f"lost requests: auto={auto_lost} fleet={fleet_lost}"
        assert auto_identical, \
            "auto-bucket responses drifted from sequential padded_predict"
        assert fleet_identical, \
            "fleet responses drifted from sequential padded_predict"
        assert n_evictions >= 1, \
            f"budget {budget} < resident {resident} yet nothing evicted"
        assert fleet_typed == 0, \
            f"{fleet_typed} typed failures in an unfaulted fleet replay"
        assert auto_p99 <= p99_bound, \
            f"auto p99 {auto_p99:.1f} ms exceeds bound {p99_bound:.1f} ms"
        assert fleet_p99_ok, \
            f"fleet p99 {fleet_p99} exceeds bound {p99_bound:.1f} ms"
        print("smoke assertions passed (solver not worse, zero lost, "
              "bit-identical, evictions observed, p99 bounded)")


if __name__ == "__main__":
    main()
