"""CI smoke for the LM serving path (ISSUE 10).

Builds a seq-bucketed ``LMSession`` (buckets solved from a synthetic
prompt-length histogram by the traffic DP), saves the v5 artifact, then
**reloads it in a separate process** (fresh interpreter, cold caches)
and gates there:

* load -> generate runs **zero** schedule searches
  (``core.local_search.search_calls()`` spy), and every generation is
  bit-identical to the tokens the parent produced before saving;
* ``AsyncServer.submit_stream`` tokens are bit-identical to the
  non-streamed ``generate`` loop (stream == batch semantics), and
  streams execute alone (batch_hist.max_size == 1).

Writes BENCH_lm.json with the solved bucket set, load/prewarm wall
times, and decode throughput from the child.

    PYTHONPATH=../src python lm_serving.py --smoke --out ../BENCH_lm.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

_CHILD = r"""
import json
import sys
import time

import numpy as np
import jax.numpy as jnp

artifact, out_json, gen = sys.argv[1], sys.argv[2], int(sys.argv[3])
from repro.core.local_search import search_calls
from repro.engine import AsyncServer, DynamicBatchPolicy, LMSession

t0 = time.perf_counter()
sess = LMSession.load(artifact)
t_load = time.perf_counter() - t0
t0 = time.perf_counter()
sess.prewarm()
t_warm = time.perf_counter() - t0

prompts = np.load(artifact + "/smoke_prompts.npz")
want = np.load(artifact + "/smoke_tokens.npz")
keys = sorted(prompts.files, key=int)

# gate 1: load -> generate is zero-search and bit-identical cross-process
t0 = time.perf_counter()
plain = {}
for k in keys:
    plain[k] = np.asarray(sess.generate(jnp.asarray(prompts[k]), gen))
t_gen = time.perf_counter() - t0
assert search_calls() == 0, \
    f"load->generate ran {search_calls()} schedule searches (want 0)"
for k in keys:
    assert plain[k].tobytes() == want[k].tobytes(), \
        f"cross-process token drift on prompt {k}"

# gate 2: streamed decode == the non-streamed loop, bit for bit, and
# each stream executed alone
srv = AsyncServer(sess, DynamicBatchPolicy(max_batch=4, max_wait_ms=1.0))
try:
    streams = [(k, srv.submit_stream(jnp.asarray(prompts[k]), gen))
               for k in keys]
    for k, s in streams:
        toks = [np.asarray(t) for t in s]
        assert len(toks) == gen, f"stream {k} yielded {len(toks)} steps"
        got = np.stack(toks, axis=1)
        assert got.tobytes() == plain[k].tobytes(), \
            f"streamed tokens drifted from generate on prompt {k}"
finally:
    srv.close(drain=True)
assert search_calls() == 0, "streaming ran a schedule search"
assert srv.stats.batch_hist.max_size == 1, \
    "a stream was packed with other requests"

n_tok = gen * len(keys) * sess.batch
json.dump({"t_load_s": round(t_load, 4), "t_prewarm_s": round(t_warm, 4),
           "decode_tok_per_s": round(n_tok / t_gen, 2),
           "n_generations": len(keys), "zero_search": True,
           "stream_bit_identical": True},
          open(out_json, "w"), indent=2)
print(f"child process: {len(keys)} generations zero-search, streamed == "
      f"generate bit-identical (seq_buckets={sess.seq_buckets})")
"""


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--max-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=5)
    ap.add_argument("--requests", type=int, default=6,
                    help="prompt count (lengths drawn from the synthetic "
                         "histogram the buckets are solved from)")
    ap.add_argument("--max-seq-buckets", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="kept for CI-lane symmetry; the benchmark is "
                         "already smoke-sized")
    ap.add_argument("--out", default="BENCH_lm.json")
    ap.add_argument("--artifact-out", default=None,
                    help="keep the LM artifact here (default: temp dir)")
    args = ap.parse_args()

    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced
    from repro.engine import compile_lm, expected_catchup_tokens

    cfg = reduced(ARCHS[args.arch])
    max_prompt = args.max_len - args.gen + 1
    # synthetic prompt-length demand: short-head + long-tail, the shape
    # the seq-bucket DP earns its keep on
    hist = {max(1, max_prompt // 4): 40, max(2, max_prompt // 2): 25,
            max_prompt: 10}
    t0 = time.perf_counter()
    sess = compile_lm(cfg, max_len=args.max_len, seq_buckets="auto",
                      prompt_hist=hist,
                      max_seq_buckets=args.max_seq_buckets, seed=0)
    t_compile = time.perf_counter() - t0
    catchup = expected_catchup_tokens(hist, sess.seq_buckets)

    rng = np.random.default_rng(0)
    lens = rng.choice(sorted(hist), size=args.requests,
                      p=np.asarray([hist[k] for k in sorted(hist)])
                      / sum(hist.values()))
    prompts = {str(i): rng.integers(0, cfg.vocab,
                                    size=(sess.batch, int(n)))
               .astype(np.int32) for i, n in enumerate(lens)}
    tokens = {k: np.asarray(sess.generate(jnp.asarray(p), args.gen))
              for k, p in prompts.items()}

    out_dir = Path(args.artifact_out) if args.artifact_out else \
        Path(tempfile.mkdtemp(prefix="lm_smoke_")) / "ARTIFACT_lm"
    sess.save(out_dir)
    np.savez(out_dir / "smoke_prompts.npz", **prompts)
    np.savez(out_dir / "smoke_tokens.npz", **tokens)
    print(f"saved LM artifact to {out_dir} (arch={args.arch}, "
          f"max_len={args.max_len}, seq_buckets={sess.seq_buckets}, "
          f"expected catch-up {catchup} decode tokens on the histogram)")

    env = dict(os.environ)
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    child_json = out_dir / "child_report.json"
    subprocess.run([sys.executable, "-c", _CHILD, str(out_dir),
                    str(child_json), str(args.gen)], check=True, env=env)
    child = json.loads(child_json.read_text())

    report = {"benchmark": "lm_serving", "arch": args.arch,
              "family": cfg.family, "max_len": args.max_len,
              "gen": args.gen, "seq_buckets": list(sess.seq_buckets),
              "prompt_hist": {str(k): v for k, v in sorted(hist.items())},
              "expected_catchup_tokens": catchup,
              "t_compile_s": round(t_compile, 4), **child}
    Path(args.out).write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {args.out}: LM artifact cross-process round-trip OK "
          f"(zero search, streamed == generate)")


if __name__ == "__main__":
    main()
