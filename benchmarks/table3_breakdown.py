"""Paper Table 3: the ablation ladder — baseline -> +layout ->
+transform-elimination -> +global-search.

Two modes:
* predicted (default): the v5e roofline objective per mode, normalized to
  the NCHW baseline — the ladder the planner optimizes for the TPU target.
* --measured: wall-clock ladder on the host CPU with the paper's own
  methodology — the local search *measures candidates on the deployment
  target* (guided: roofline prunes to top-6, measurement ranks), so the
  chosen schedules are CPU-optimal rather than TPU-optimal.  All five mode
  executables are timed round-robin through ``benchmarks/harness.py``
  (warmup-phase detection + interleaved paired medians), so one noisy
  phase cannot skew a single rung of the ladder.
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit, prepare
from benchmarks.harness import measure_paired
from repro.core.local_search import (ScheduleDatabase, guided_local_search)
from repro.core.planner import MODES

LADDER_SET = ["resnet-50", "vgg-19", "densenet-201", "inception-v3",
              "ssd-resnet-50"]


def run_predicted(models):
    rows = []
    for name in models:
        base = None
        for mode in MODES:
            _, _, p = prepare(name, mode)
            t = p.predicted_total_s
            if mode == "nchw":
                base = t
            rows.append((f"table3/{name}/{mode}", t * 1e6,
                         f"speedup_vs_nchw={base / t:.2f}x;"
                         f"transforms={p.planned.n_transforms}"))
        print(f"# {name} predicted ladder done", flush=True)
    return rows


def run_measured(name: str, repeats: int = 3):
    """CPU-measured ladder with measured local search (paper methodology)."""
    rows = []
    db = ScheduleDatabase()

    class GuidedDB(ScheduleDatabase):
        def search(self, wl, runner=None, max_candidates=64):
            from repro.core.local_search import _wl_key
            key = _wl_key(wl)
            if key not in self._mem:
                self._mem[key] = guided_local_search(wl)
            return self._mem[key]

    gdb = GuidedDB()
    models = []
    for mode in MODES:
        # measured-on-CPU target: the paper's x=16 (AVX-512 fp32 lanes) is
        # the right constant block here, not the TPU's 128
        m, x, _ = prepare(name, mode, db=gdb, uniform_block=16)
        models.append((mode, m, x))
    # one interleaved paired run across the whole ladder: every mode is
    # sampled in every round, so medians are comparable rung to rung
    timings = measure_paired(
        [(lambda m=m, x=x: m.predict(x)) for _, m, x in models],
        repeats=repeats)
    base = timings[0].median_ms
    for (mode, _, _), t in zip(models, timings):
        rows.append((f"table3-measured/{name}/{mode}", t.median_ms * 1e3,
                     f"speedup_vs_nchw={base / t.median_ms:.2f}x;"
                     f"min_ms={t.min_ms:.2f};warmup={t.warmup_rounds}"))
        print(f"# measured {name}/{mode}: {t.median_ms:.1f} ms "
              f"({base / t.median_ms:.2f}x, paired medians)", flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--measured", action="store_true")
    ap.add_argument("--model", default="resnet-18")
    ap.add_argument("--models", nargs="*", default=LADDER_SET)
    args = ap.parse_args(argv)
    rows = run_measured(args.model) if args.measured \
        else run_predicted(args.models)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
