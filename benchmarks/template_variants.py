"""Template-variant benchmark (§3.2 + §3.3): per-layer and end-to-end
numbers for every conv lowering variant against the PR-1 baseline.

Three plans per model, all §3.1-fused, all on the jnp path:

* ``pr1``      — the PR-1 search space re-planned: blockings capped at the
                 128-lane factor, lowering fixed to the static ``auto``
                 heuristic (tap_stack below sublane ic_bn, per_tap
                 otherwise).  This is the shipped PR-1 template.
* ``searched`` — the variant-aware measured search: per workload, the
                 roofline model prunes the (blocking x variant) space and
                 wall-clock measurement on this host picks the winner
                 (``ScheduleDatabase.search_measured``); the global search
                 then assigns layouts as usual.  Winners (variant included)
                 persist in the workload-keyed schedule database
                 (``--db``, default BENCH_variants_db.json).
* ``forced:<v>`` — every conv forced to variant ``v`` at its best measured
                 blocking *for that variant*: the per-variant end-to-end
                 ablation.

Per-layer numbers come from the measured search's ranked lists: for each
unique conv workload, the best measured ms of every variant.

Measurement rides on ``benchmarks/harness.py`` (warmup-phase detection +
interleaved paired medians) — the same methodology as BENCH_fusion.json.
Emits ``BENCH_variants.json``.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from harness import measure_paired
from repro.core.cost import conv_schedule_cost
from repro.core.fusion import fuse_graph
from repro.core.local_search import (LocalSearchResult, ScheduleDatabase,
                                     _wl_key)
from repro.core.pipeline import Pipeline, make_workload
from repro.core.schedule import VARIANTS, ConvSchedule, ConvWorkload
from repro.engine import compile_model
from repro.engine.calibrate import measure_host_copy_bw
from repro.models.cnn import build
from repro.nn.init import init_params

_BIG = 1e9


def pr1_runner(wl: ConvWorkload, s: ConvSchedule) -> float:
    """Roofline cost restricted to the PR-1 search space: blockings up to
    the 128-lane cap, lowering = the static heuristic.  Everything outside
    that space is priced out, so the plan reproduces the PR-1 template."""
    if s.resolved_variant() != ("tap_stack" if s.ic_bn < 8 else "per_tap"):
        return _BIG
    if s.ic_bn > 128 or s.oc_bn > 128:
        return _BIG
    return conv_schedule_cost(wl, s).total_s


def _as_auto(planned_schedules: Dict[str, ConvSchedule]) -> None:
    """Rewrite a plan's schedules to variant='auto' in place — the engine
    then runs exactly the PR-1 kernel dispatch."""
    import dataclasses
    for name, s in list(planned_schedules.items()):
        planned_schedules[name] = dataclasses.replace(s, variant="auto")


def fused_workloads(model: str, batch: int, image: int):
    """(graph, shapes, [(node_name, workload)]) for the §3.1-fused model."""
    g, shapes = build(model, batch=batch, image=image)
    g.infer_shapes(shapes)
    fg, _ = fuse_graph(g)
    fg.infer_shapes(shapes)
    wls = [(n.name, make_workload(n, fg.nodes[n.inputs[0]].shape))
           for n in fg.conv_nodes()]
    return g, shapes, wls


def per_variant_best(res: LocalSearchResult) -> Dict[str, dict]:
    out: Dict[str, dict] = {}
    for r in res.ranked:
        v = r.schedule.resolved_variant()
        if v not in out:
            out[v] = {"ms": round(r.cost_s * 1e3, 3),
                      "ic_bn": r.schedule.ic_bn, "oc_bn": r.schedule.oc_bn}
    return out


def run_model(model: str, batch: int, image: int, repeats: int,
              db: ScheduleDatabase, top_k: int, per_variant: int,
              search_repeats: int, forced: bool, op_dispatch: bool) -> dict:
    g, shapes, wls = fused_workloads(model, batch, image)
    params = init_params(g, shapes, seed=0)
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=shapes["data"]).astype(np.float32))

    # -- per-layer: variant-aware measured search per unique workload -------
    layers = {}
    for name, wl in wls:
        res = db.search_measured(wl, top_k=top_k, per_variant=per_variant,
                                 repeats=search_repeats)
        key = _wl_key(wl)
        if key not in layers:
            best = res.best
            layers[key] = {
                "example_node": name,
                "variants": per_variant_best(res),
                "winner": {"variant": best.resolved_variant(),
                           "ic_bn": best.ic_bn, "oc_bn": best.oc_bn},
            }
    n_non_per_tap = sum(1 for rec in layers.values()
                        if rec["winner"]["variant"] != "per_tap")
    print(f"{model}: {len(layers)} unique workloads, "
          f"{n_non_per_tap} non-per_tap winners", flush=True)

    # -- plans ---------------------------------------------------------------
    # the "searched"/"forced" runs hold measured db entries, so the fusion
    # pipeline auto-calibrates the host transform bandwidth itself (no more
    # hand-measured transform_bw threaded through every call)
    fusion = Pipeline.preset("fusion")
    base_plan = fusion.run(g, shapes, db=ScheduleDatabase(),
                           runner=pr1_runner)
    _as_auto(base_plan.planned.schedules)
    searched_plan = fusion.run(g, shapes, db=db, tuning="cached")

    plans = {"pr1": base_plan, "searched": searched_plan}
    if forced:
        for v in VARIANTS:
            db_v = ScheduleDatabase()
            for _, wl in wls:
                res = db.search_measured(wl)   # memoized
                ranked_v = [r for r in res.ranked
                            if r.schedule.resolved_variant() == v]
                db_v.put(wl, LocalSearchResult(wl, ranked_v or res.ranked,
                                               measured=True))
            plans[f"forced:{v}"] = fusion.run(g, shapes, db=db_v,
                                              tuning="cached")

    # -- end-to-end, whole-graph jit (headline) ------------------------------
    result = {"model": model, "batch": batch, "image": image,
              "repeats": repeats, "path": "jnp",
              "n_workloads": len(layers),
              "n_non_per_tap_winners": n_non_per_tap,
              "layers": layers}
    names = list(plans)
    models = {n: compile_model(plans[n], params) for n in names}
    timings = measure_paired([(lambda m=models[n]: m.predict(x))
                              for n in names], repeats=repeats)
    whole = {}
    base_ms = timings[names.index("pr1")].median_ms
    for n, t in zip(names, timings):
        whole[n] = t.to_json()
        whole[n]["speedup_vs_pr1"] = round(base_ms / t.median_ms, 3)
        print(f"{model} whole-jit {n:18s}: {t.median_ms:8.2f}ms "
              f"({base_ms / t.median_ms:.3f}x vs pr1)", flush=True)
    result["whole_jit"] = whole
    result["speedup"] = whole["searched"]["speedup_vs_pr1"]

    # -- end-to-end, graph-runtime dispatch (baseline execution model) -------
    if op_dispatch:
        mb = compile_model(base_plan, params, dispatch="op")
        ms = compile_model(searched_plan, params, dispatch="op")
        t_b, t_s = measure_paired(
            [lambda: mb.predict(x), lambda: ms.predict(x)], repeats=repeats)
        result["op_dispatch"] = {
            "pr1": t_b.to_json(), "searched": t_s.to_json(),
            "speedup": round(t_b.median_ms / t_s.median_ms, 3)}
        print(f"{model} op-dispatch searched: "
              f"{t_s.median_ms:.2f}ms vs pr1 {t_b.median_ms:.2f}ms "
              f"({result['op_dispatch']['speedup']:.3f}x)", flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", default="resnet-18,vgg-16,densenet-121")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--image", type=int, default=224)
    ap.add_argument("--repeats", type=int, default=20)
    ap.add_argument("--top-k", type=int, default=6)
    ap.add_argument("--per-variant", type=int, default=2)
    ap.add_argument("--search-repeats", type=int, default=5)
    ap.add_argument("--forced-models", default="resnet-18",
                    help="models that also get the per-variant forced "
                         "end-to-end ablation (6 whole-graph compiles)")
    ap.add_argument("--no-op-dispatch", action="store_true")
    ap.add_argument("--out", default="BENCH_variants.json")
    ap.add_argument("--db", default="BENCH_variants_db.json",
                    help="workload-keyed schedule database (persisted; "
                         "records the measured (variant, blocking) winners)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one small model, tiny search budget")
    args = ap.parse_args()
    if args.smoke:
        args.models, args.image, args.repeats = "resnet-18", 64, 3
        args.top_k, args.per_variant, args.search_repeats = 2, 1, 2
        args.forced_models = ""
        args.no_op_dispatch = True

    db = ScheduleDatabase(args.db)
    forced = set(filter(None, args.forced_models.split(",")))
    # the same process-cached probe the pipeline's GlobalLayoutPlan uses
    bw = measure_host_copy_bw()
    print(f"host relayout bandwidth: {bw / 1e9:.2f} GB/s "
          f"(auto-calibrated, reused by every plan below)", flush=True)
    out = {"harness": "paired-interleaved medians + warmup-phase detection",
           "host_transform_bw_gbps": round(bw / 1e9, 3),
           "models": {}}
    for model in filter(None, args.models.split(",")):
        out["models"][model] = run_model(
            model, args.batch, args.image, args.repeats, db,
            args.top_k, args.per_variant, args.search_repeats,
            forced=model in forced, op_dispatch=not args.no_op_dispatch)
    first = next(iter(out["models"]))
    out["speedup"] = out["models"][first]["speedup"]
    out["non_per_tap_winners"] = sum(
        m["n_non_per_tap_winners"] for m in out["models"].values())
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out} (headline {first} whole-jit searched "
          f"{out['speedup']:.3f}x vs pr1; "
          f"{out['non_per_tap_winners']} non-per_tap workload winners)")


if __name__ == "__main__":
    main()
