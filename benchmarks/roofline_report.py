"""Aggregate the dry-run JSONs into the EXPERIMENTS.md roofline table.

Where an unrolled-scan measurement twin exists
(experiments/perf/<arch>__<shape>__<mesh>__baseline+unroll.json), its
collective bytes replace the scanned parse (marked *): the layer scan hides
per-layer collectives from the HLO text parse by ~n_layers (methodology in
EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1] / "experiments"
DRYRUN_DIR = ROOT / "dryrun"
PERF_DIR = ROOT / "perf"

ICI_BW = 50e9


def load(mesh: str):
    rows = []
    for p in sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        twin = PERF_DIR / (f"{rec['arch']}__{rec['shape']}__{mesh}"
                           "__baseline+unroll.json")
        if rec.get("status") == "ok" and twin.exists():
            t = json.loads(twin.read_text())
            if t.get("status") == "ok":
                rec["roofline"]["collective_bytes_per_device"] = \
                    t["collective_bytes"]["total"]
                rec["roofline"]["collective_s"] = \
                    t["collective_bytes"]["total"] / ICI_BW
                rec["unrolled_twin"] = True
                rl = rec["roofline"]
                step = max(rl["compute_s"], rl["memory_s"]) \
                    + rl["collective_s"]
                rl["step_time_s"] = step
                rl["roofline_fraction"] = rl["ideal_step_s"] / step
                terms = {"compute": rl["compute_s"],
                         "memory": rl["memory_s"],
                         "collective": rl["collective_s"]}
                rl["bottleneck"] = max(terms, key=terms.get)
        rows.append(rec)
    return rows


def fmt_table(rows, skip_skipped=False):
    out = ["| arch | shape | status | compute_s | memory_s | collective_s |"
           " bottleneck | useful | roofline | HBM/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            if not skip_skipped:
                out.append(f"| {r['arch']} | {r['shape']} | {r['status']} |"
                           " - | - | - | - | - | - | - |")
            continue
        rl = r["roofline"]
        mem = r["memory"]
        hbm = (mem.get("temp_bytes") or 0) / 2**30
        star = "*" if r.get("unrolled_twin") else ""
        out.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {rl['compute_s']:.3e} | {rl['memory_s']:.3e} "
            f"| {rl['collective_s']:.3e}{star} | {rl['bottleneck']} "
            f"| {rl['useful_flops_ratio']:.2f} "
            f"| {rl['roofline_fraction']:.3f} | {hbm:.1f} GiB |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)
    rows = load(args.mesh)
    print(fmt_table(rows))
    print("name,us_per_call,derived")
    for r in rows:
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        print(f"roofline/{r['arch']}/{r['shape']}/{args.mesh},"
              f"{rl['step_time_s'] * 1e6:.1f},"
              f"bottleneck={rl['bottleneck']};"
              f"fraction={rl['roofline_fraction']:.3f}")
    return rows


if __name__ == "__main__":
    main()
