"""Paper Table 2: overall inference latency per network.

Columns here: measured XLA-CPU wall time for the NCHW baseline graph vs the
fully-planned (global-search) graph, and the v5e roofline-model predicted
latency for both — the prediction is what carries the paper's ladder to the
TPU target; the measured pair shows the planned graph is never semantically
or pathologically worse end-to-end on the host.

Measurement rides on ``benchmarks/harness.py`` (warmup-phase detection +
interleaved paired medians): both graphs of a network are timed round-robin
within each round, so a noisy phase on this shared host hits both equally
and the reported medians stay comparable.

Default: the paper's 5 ablation networks (one per family).  --full: all 15
(slow on 1 CPU core).  batch=1, full image sizes, as in the paper.
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit, prepare
from benchmarks.harness import measure_paired


# measured subset for the default run (1 CPU core); --full = all 15
ABLATION_SET = ["resnet-50", "vgg-19", "inception-v3"]
FULL_SET = [f"resnet-{d}" for d in (18, 34, 50, 101, 152)] \
    + [f"vgg-{d}" for d in (11, 13, 16, 19)] \
    + [f"densenet-{d}" for d in (121, 161, 169, 201)] \
    + ["inception-v3", "ssd-resnet-50"]


def run(models, repeats: int = 3):
    rows = []
    for name in models:
        m0, x, p0 = prepare(name, "nchw")
        m1, _, p1 = prepare(name, "global-search")
        t0, t1 = measure_paired(
            [lambda: m0.predict(x), lambda: m1.predict(x)], repeats=repeats)
        rows.append((f"table2/{name}/nchw-measured", t0.median_ms * 1e3,
                     f"pred_v5e_us={p0.predicted_total_s * 1e6:.1f};"
                     f"min_ms={t0.min_ms:.2f};warmup={t0.warmup_rounds}"))
        rows.append((f"table2/{name}/planned-measured", t1.median_ms * 1e3,
                     f"pred_v5e_us={p1.predicted_total_s * 1e6:.1f};"
                     f"pred_speedup="
                     f"{p0.predicted_total_s / p1.predicted_total_s:.2f}x;"
                     f"measured_speedup={t0.median_ms / t1.median_ms:.2f}x;"
                     f"transforms={p1.planned.n_transforms};"
                     f"solver={p1.solution.method if p1.solution else '-'}"))
        print(f"# {name}: measured {t0.median_ms:.1f} -> {t1.median_ms:.1f} "
              f"ms (paired medians, {t0.warmup_rounds} warmup rounds) | "
              f"v5e predicted {p0.predicted_total_s * 1e3:.3f} -> "
              f"{p1.predicted_total_s * 1e3:.3f} ms", flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    rows = run(FULL_SET if args.full else ABLATION_SET, args.repeats)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
