"""Paper Figure 4: parallel-scaling study.

NeoCPU's figure compares thread-pool vs OpenMP scalability on one CPU.
On the TPU target the analogue is scaling efficiency across chips: mesh
parallelism replaces the thread pool, and the cost of growing the "pool"
is the collective roofline term instead of fork-join overhead.  We sweep
chip counts, derive throughput from the three roofline terms for a fixed
per-chip workload (weak scaling, NeoCPU's images/sec framing), and report
efficiency vs the ideal linear line.  The collective term is computed for
ring reductions over the DP axis (gradient bytes = active params).

``--measured`` adds the host-CPU analogue of the figure through
``benchmarks/harness.py`` (warmup-phase detection + interleaved paired
medians): batch weak scaling of a planned CNN — all batch sizes timed
round-robin so the images/sec efficiency curve is phase-noise-robust, the
same framing (throughput vs ideal linear) as the paper's thread sweep.
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit
from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.configs import ARCHS

CHIPS = (1, 2, 4, 8, 16, 32, 64, 128, 256)
BATCHES = (1, 2, 4)


def run_measured(model: str = "resnet-18", image: int = 112,
                 repeats: int = 10):
    """Batch weak scaling on the host: one planned executable per batch
    size, all sampled in every harness round (paired medians)."""
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import _DB
    from benchmarks.harness import measure_paired
    from repro.engine import compile as compile_session
    from repro.models.cnn import build

    # ONE session, specialized per batch size — the weak-scaling sweep is
    # exactly the per-batch specialization the InferenceSession owns
    g, shapes = build(model, batch=BATCHES[0], image=image)
    session = compile_session(g, shapes, db=_DB, eager=False)
    setups = []
    for b in BATCHES:
        m = session.specialize(b)
        x = jnp.asarray(np.random.default_rng(0)
                        .normal(size=(b,) + shapes["data"][1:])
                        .astype(np.float32))
        setups.append((b, m, x))
    timings = measure_paired(
        [(lambda m=m, x=x: m.predict(x)) for _, m, x in setups],
        repeats=repeats)
    rows = []
    base_ips = BATCHES[0] / (timings[0].median_ms * 1e-3)
    for (b, _, _), t in zip(setups, timings):
        ips = b / (t.median_ms * 1e-3)
        eff = ips / (base_ips * b / BATCHES[0])
        rows.append((f"figure4-measured/{model}/batch={b}",
                     t.median_ms * 1e3,
                     f"images_per_s={ips:.2f};efficiency={eff:.3f};"
                     f"warmup={t.warmup_rounds}"))
        print(f"# batch={b}: {t.median_ms:.1f} ms  {ips:.1f} img/s  "
              f"efficiency={eff:.3f} (paired medians)", flush=True)
    return rows


def throughput(cfg, n_chips: int, per_chip_batch: int, seq: int):
    """Weak-scaling tokens/sec: compute+memory fixed per chip; the ring
    all-reduce of the gradients adds 2 x bytes x (n-1)/n over ICI."""
    n_active = cfg.active_param_count()
    tokens = per_chip_batch * seq
    flops = 6.0 * n_active * tokens
    compute_s = flops / PEAK_FLOPS
    # params + grads + opt moments traffic, plus activations ~ 2 x flops/AI
    mem_bytes = 2 * n_active * 2 + 12 * n_active + tokens * cfg.d_model * 8
    memory_s = mem_bytes / HBM_BW
    grad_bytes = 2 * n_active
    coll_s = 0.0 if n_chips == 1 else \
        2 * grad_bytes * (n_chips - 1) / n_chips / ICI_BW
    step = max(compute_s, memory_s) + coll_s
    return n_chips * tokens / step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--per-chip-batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--measured", action="store_true",
                    help="host-CPU batch weak scaling via the paired-median "
                         "harness instead of the analytical chip sweep")
    ap.add_argument("--model", default="resnet-18")
    ap.add_argument("--image", type=int, default=112)
    args = ap.parse_args(argv)
    if args.measured:
        rows = run_measured(args.model, args.image)
        emit(rows)
        return rows
    cfg = ARCHS[args.arch]
    rows = []
    base = throughput(cfg, 1, args.per_chip_batch, args.seq)
    for n in CHIPS:
        tp = throughput(cfg, n, args.per_chip_batch, args.seq)
        eff = tp / (base * n)
        rows.append((f"figure4/{cfg.name}/chips={n}",
                     1e6 * n * args.per_chip_batch * args.seq / tp,
                     f"tokens_per_s={tp:.3e};efficiency={eff:.3f}"))
        print(f"# chips={n:4d} tokens/s={tp:.3e} efficiency={eff:.3f}",
              flush=True)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
