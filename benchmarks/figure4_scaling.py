"""Paper Figure 4: parallel-scaling study.

NeoCPU's figure compares thread-pool vs OpenMP scalability on one CPU.
On the TPU target the analogue is scaling efficiency across chips: mesh
parallelism replaces the thread pool, and the cost of growing the "pool"
is the collective roofline term instead of fork-join overhead.  We sweep
chip counts, derive throughput from the three roofline terms for a fixed
per-chip workload (weak scaling, NeoCPU's images/sec framing), and report
efficiency vs the ideal linear line.  The collective term is computed for
ring reductions over the DP axis (gradient bytes = active params).
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit
from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.configs import ARCHS

CHIPS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def throughput(cfg, n_chips: int, per_chip_batch: int, seq: int):
    """Weak-scaling tokens/sec: compute+memory fixed per chip; the ring
    all-reduce of the gradients adds 2 x bytes x (n-1)/n over ICI."""
    n_active = cfg.active_param_count()
    tokens = per_chip_batch * seq
    flops = 6.0 * n_active * tokens
    compute_s = flops / PEAK_FLOPS
    # params + grads + opt moments traffic, plus activations ~ 2 x flops/AI
    mem_bytes = 2 * n_active * 2 + 12 * n_active + tokens * cfg.d_model * 8
    memory_s = mem_bytes / HBM_BW
    grad_bytes = 2 * n_active
    coll_s = 0.0 if n_chips == 1 else \
        2 * grad_bytes * (n_chips - 1) / n_chips / ICI_BW
    step = max(compute_s, memory_s) + coll_s
    return n_chips * tokens / step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--per-chip-batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=4096)
    args = ap.parse_args(argv)
    cfg = ARCHS[args.arch]
    rows = []
    base = throughput(cfg, 1, args.per_chip_batch, args.seq)
    for n in CHIPS:
        tp = throughput(cfg, n, args.per_chip_batch, args.seq)
        eff = tp / (base * n)
        rows.append((f"figure4/{cfg.name}/chips={n}",
                     1e6 * n * args.per_chip_batch * args.seq / tp,
                     f"tokens_per_s={tp:.3e};efficiency={eff:.3f}"))
        print(f"# chips={n:4d} tokens/s={tp:.3e} efficiency={eff:.3f}",
              flush=True)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
