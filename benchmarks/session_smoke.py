"""CI smoke for the InferenceSession artifact path.

Builds a session with ``tuning="cached"``, saves the versioned artifact,
then **reloads it in a separate process** (a real ``subprocess`` — fresh
interpreter, cold caches) and runs one predict there, asserting

* the loaded output is bit-identical to the in-process session's, and
* the load->predict path ran **zero** schedule searches
  (``core.local_search.search_calls()`` spy — trivially exact in a fresh
  process, where any search would move the counter off zero).

The artifact directory is left on disk so CI uploads it alongside the
BENCH_*.json files.

    PYTHONPATH=../src python session_smoke.py --out ../ARTIFACT_session
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from pathlib import Path

import numpy as np

_CHILD = r"""
import sys
import numpy as np
import jax.numpy as jnp

artifact = sys.argv[1]
from repro.core.local_search import search_calls
from repro.engine import InferenceSession

sess = InferenceSession.load(artifact)
x = np.load(artifact + "/smoke_input.npy")
want = np.load(artifact + "/smoke_output.npy")
got = np.asarray(sess.predict(jnp.asarray(x)))
assert search_calls() == 0, \
    f"load->predict ran {search_calls()} schedule searches (want 0)"
assert got.shape == want.shape and got.tobytes() == want.tobytes(), \
    f"cross-process drift: max|delta|={np.abs(got - want).max()}"
print(f"child process: predict bit-identical, zero search "
      f"(batches={sess.batch_sizes}, frozen={sess.frozen})")
"""


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="resnet-18")
    ap.add_argument("--image", type=int, default=64)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--batches", default=None,
                    help="extra batch sizes to specialize+save (comma "
                         "list, e.g. '1,8') — the serving buckets the "
                         "serving_load benchmark packs into")
    ap.add_argument("--db", default=None,
                    help="schedule database to serve cached winners from "
                         "(e.g. BENCH_variants_db.json); omitted = "
                         "roofline-filled cache")
    ap.add_argument("--out", default="ARTIFACT_session")
    args = ap.parse_args()

    import jax.numpy as jnp
    from repro.engine import compile as compile_session

    if args.db and not Path(args.db).exists():
        # fail loudly: CI passes the smoke variants db so the cached path
        # exercises measured winners — a typo'd/reordered path must not
        # silently degrade this step to an empty cache
        raise SystemExit(f"--db {args.db} does not exist")
    sess = compile_session(args.model,
                           (args.batch, 3, args.image, args.image),
                           tuning="cached", db=args.db)
    for b in sorted(int(s) for s in (args.batches or "").split(",") if s):
        sess.specialize(b)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(args.batch, 3, args.image, args.image)) \
        .astype(np.float32)
    y = np.asarray(sess.predict(jnp.asarray(x)))
    out = Path(args.out)
    sess.save(out)
    np.save(out / "smoke_input.npy", x)
    np.save(out / "smoke_output.npy", y)
    print(f"saved artifact to {out} (model={args.model}, "
          f"image={args.image}, batch={args.batch})")

    env = dict(os.environ)
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    subprocess.run([sys.executable, "-c", _CHILD, str(out)],
                   check=True, env=env)
    print("session artifact cross-process round-trip OK")


if __name__ == "__main__":
    main()
