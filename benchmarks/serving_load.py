"""Serving load benchmark: dynamic batching vs sequential serving of one
InferenceSession artifact, on the shared measurement harness.

Serves the same mixed-size request stream two ways from a cold-loaded
artifact and reports paired medians (``harness.measure_paired``) plus the
driver's latency percentiles into ``BENCH_serving.json``:

* **sequential** — one request at a time through ``padded_predict`` at the
  driver's bucket: the batch=1 serving baseline of the same deterministic
  artifact (every request pays a full bucket execution);
* **driver** — the ``AsyncServer`` packs the stream into bucket-sized
  batches (``DynamicBatchPolicy(fixed_bucket=...)``, so results are
  bit-reproducible regardless of packing);
* **sequential-native** (informational, not part of the acceptance pair) —
  per-request nearest-bucket execution, the fastest non-deterministic
  sequential path.

``--workers N`` runs the driver with N worker threads (per-device program
replicas when the host exposes that many devices — see
``launch.cpu.configure_cpu_devices``); packing stays FIFO and
bucket-fixed, so responses stay bit-identical regardless of worker count.

``--smoke`` (CI, against the ``session_smoke`` artifact) asserts the
driver's responses bit-match sequential serving, the whole serve ran zero
schedule searches, p50/p99 are reported, and the paired-median throughput
gain is >= ``--min-speedup`` (default 2x; the CI multi-core lane raises
it).

    PYTHONPATH=../src python serving_load.py --smoke \
        --artifact ../ARTIFACT_session --out ../BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

import harness


def build_requests(session, sizes, n_requests, seed):
    import jax.numpy as jnp

    (name,) = session.input_spec
    tail = session.input_spec[name][1:]
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_requests):
        rows = sizes[i % len(sizes)]
        out.append(jnp.asarray(
            rng.normal(size=(rows,) + tail).astype(np.float32)))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifact", default=None,
                    help="saved InferenceSession artifact dir; omitted = "
                         "build one from --model on the fly")
    ap.add_argument("--model", default="resnet-18")
    ap.add_argument("--image", type=int, default=32)
    ap.add_argument("--bucket", type=int, default=8,
                    help="the driver's (and the sequential baseline's) "
                         "execution bucket; must be specialized in the "
                         "artifact")
    ap.add_argument("--sizes", default="1,2,3",
                    help="request row counts, cycled over the stream")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    ap.add_argument("--workers", type=int, default=1,
                    help="AsyncServer worker threads; >1 needs as many "
                         "host devices (see launch.cpu) for the replicas "
                         "to land on distinct cores")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="--smoke gate on the paired-median driver-vs-"
                         "sequential throughput gain")
    ap.add_argument("--repeats", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small stream + hard assertions "
                         "(bit-identical, zero search, >=2x throughput)")
    args = ap.parse_args()

    if args.workers > 1:
        # replicas need that many host devices; must precede the first
        # jax computation (imports alone don't lock the device count)
        from repro.launch.cpu import configure_cpu_devices
        configure_cpu_devices(args.workers, warn_oversubscribe=False)

    import jax
    import jax.numpy as jnp

    from repro.core.local_search import search_calls
    from repro.engine import (AsyncServer, DynamicBatchPolicy,
                              InferenceSession, nearest_bucket,
                              padded_predict)
    from repro.engine import compile as compile_session

    sizes = [int(s) for s in args.sizes.split(",")]
    if args.smoke:
        args.repeats = min(args.repeats, 6)

    if args.artifact is None:
        import tempfile
        tmp = tempfile.TemporaryDirectory(prefix="neocpu_serving_bench_")
        art = Path(tmp.name) / "artifact"
        sess = compile_session(args.model,
                               (1, 3, args.image, args.image))
        for b in sorted({1, args.bucket}):
            sess.specialize(b)
        sess.save(art)
    else:
        art = Path(args.artifact)

    n0 = search_calls()
    t0 = time.perf_counter()
    session = InferenceSession.load(art)
    t_load = time.perf_counter() - t0
    if args.bucket not in session.batch_sizes:
        raise SystemExit(f"--bucket {args.bucket} not specialized in "
                         f"{art} (has {session.batch_sizes})")

    requests = build_requests(session, sizes, args.requests, args.seed)
    total_rows = sum(int(x.shape[0]) for x in requests)

    def serve_sequential():
        out = None
        for x in requests:
            out = jax.block_until_ready(
                padded_predict(session, x, bucket=args.bucket))
        return out

    def serve_native():
        out = None
        for x in requests:
            out = jax.block_until_ready(padded_predict(session, x))
        return out

    policy = DynamicBatchPolicy(max_batch=args.bucket,
                                max_wait_ms=args.max_wait_ms,
                                fixed_bucket=args.bucket)

    def serve_driver():
        with AsyncServer(session, policy, max_queue=len(requests),
                         workers=args.workers) as srv:
            futs = [srv.submit(x) for x in requests]
            outs = [f.result() for f in futs]
        return outs[-1]

    # correctness first: driver responses bit-match sequential serving
    refs = [np.asarray(padded_predict(session, x, bucket=args.bucket))
            for x in requests]
    with AsyncServer(session, policy, max_queue=len(requests),
                     workers=args.workers) as probe:
        futs = [probe.submit(x) for x in requests]
        got = [np.asarray(f.result()) for f in futs]
    probe_stats = probe.stats
    bit_identical = all(a.shape == b.shape and a.tobytes() == b.tobytes()
                        for a, b in zip(got, refs))

    t_seq, t_drv, t_nat = harness.measure_paired(
        [serve_sequential, serve_driver, serve_native],
        repeats=args.repeats)
    n_searches = search_calls() - n0

    speedup = t_seq.median_ms / t_drv.median_ms
    record = {
        "benchmark": "serving_load",
        "artifact": str(art),
        "model": session.model_name,
        "input_spec": {k: list(v) for k, v in session.input_spec.items()},
        "buckets": session.batch_sizes,
        "bucket": args.bucket,
        "request_sizes": sizes,
        "n_requests": args.requests,
        "total_rows": total_rows,
        "max_wait_ms": args.max_wait_ms,
        "workers": args.workers,
        "load_ms": round(t_load * 1e3, 1),
        "sequential": t_seq.to_json(),
        "driver": t_drv.to_json(),
        "sequential_native": t_nat.to_json(),
        "throughput_req_s": {
            "sequential": round(args.requests / (t_seq.median_ms / 1e3), 1),
            "driver": round(args.requests / (t_drv.median_ms / 1e3), 1),
            "sequential_native": round(
                args.requests / (t_nat.median_ms / 1e3), 1),
        },
        "speedup_paired_median": round(speedup, 2),
        "latency_ms": {"p50": round(probe_stats.percentile_ms(50), 2),
                       "p90": round(probe_stats.percentile_ms(90), 2),
                       "p99": round(probe_stats.percentile_ms(99), 2)},
        "driver_stats": probe_stats.to_json(),
        "bit_identical_vs_sequential": bit_identical,
        "schedule_searches": n_searches,
    }
    Path(args.out).write_text(json.dumps(record, indent=2))
    print(f"artifact={art} buckets={session.batch_sizes} "
          f"load={t_load * 1e3:.0f} ms, stream of {args.requests} requests "
          f"({total_rows} rows, sizes {sizes})")
    print(f"sequential  {t_seq.median_ms:8.1f} ms/stream")
    print(f"driver      {t_drv.median_ms:8.1f} ms/stream  "
          f"({speedup:.2f}x, {probe_stats.n_batches} batches, "
          f"{probe_stats.rows_padded} padded rows)")
    print(f"native seq  {t_nat.median_ms:8.1f} ms/stream (informational)")
    print(f"latency p50={record['latency_ms']['p50']} "
          f"p99={record['latency_ms']['p99']} ms  "
          f"bit_identical={bit_identical}  searches={n_searches}")
    print(f"wrote {args.out}")

    if args.smoke:
        assert bit_identical, \
            "driver responses must bit-match sequential serving"
        assert n_searches == 0, \
            f"cold-artifact serving ran {n_searches} schedule searches"
        assert np.isfinite(record["latency_ms"]["p50"]), "p50 missing"
        assert np.isfinite(record["latency_ms"]["p99"]), "p99 missing"
        assert speedup >= args.min_speedup, \
            (f"dynamic batching speedup {speedup:.2f}x < "
             f"{args.min_speedup}x")
        print("smoke assertions passed (bit-identical, zero-search, "
              f"{speedup:.2f}x >= {args.min_speedup}x)")


if __name__ == "__main__":
    main()
