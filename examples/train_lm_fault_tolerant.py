"""Fault-tolerant LM training demo: checkpoint/restart + elastic re-mesh +
straggler mitigation, driven end-to-end on CPU with a reduced config.

    PYTHONPATH=src python examples/train_lm_fault_tolerant.py

Simulates a 4-host fleet training a reduced qwen2: host 2 dies at step 12
(heartbeat timeout), the supervisor re-plans the mesh over the 3 survivors,
restores the latest checkpoint, re-slices the deterministic data stream,
and training continues — the loss curve after recovery continues from the
checkpointed trajectory.  A straggler is detected and its batch share is
rebalanced.
"""
import sys

sys.path.insert(0, "src")

import jax                                            # noqa: E402
import jax.numpy as jnp                               # noqa: E402

from repro.checkpoint import CheckpointStore          # noqa: E402
from repro.configs import ARCHS, reduced              # noqa: E402
from repro.data import DataConfig, SyntheticLMStream  # noqa: E402
from repro.models.lm import init_params, loss_fn      # noqa: E402
from repro.optim import AdamW                         # noqa: E402
from repro.runtime import (HeartbeatMonitor,          # noqa: E402
                           StragglerMitigator, StragglerPolicy,
                           plan_elastic_mesh, rebalanced_batch_split)


def main():
    cfg = reduced(ARCHS["qwen2-1.5b"])
    opt = AdamW(lr=1e-3)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    store = CheckpointStore("/tmp/repro_ft_demo")

    hosts = [0, 1, 2, 3]
    clock = [0.0]
    mon = HeartbeatMonitor(hosts, timeout_s=3.0, clock=lambda: clock[0])
    strag = StragglerMitigator(hosts, StragglerPolicy(slow_factor=1.5,
                                                      evict_after=3))
    dc = DataConfig(global_batch=8, seq_len=32, vocab=cfg.vocab)
    stream = SyntheticLMStream(dc, cfg)

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch)
        params, opt_state, _ = opt.update(g, opt_state, params)
        return params, opt_state, loss

    alive = list(hosts)
    for step in range(25):
        clock[0] += 1.0
        # hosts post heartbeats; host 2 goes silent from step 12
        for h in alive:
            if not (h == 2 and step >= 12):
                mon.beat(h)
        dead = mon.check()
        if dead:
            print(f"step {step}: hosts {dead} FAILED — re-meshing")
            alive = mon.alive
            d, m = plan_elastic_mesh(len(alive) * 64, model_axis=16)
            print(f"  elastic plan over {len(alive) * 64} chips: "
                  f"mesh ({d}, {m})")
            (params, opt_state), ck_step, _ = store.restore(
                (params, opt_state))
            print(f"  restored checkpoint @ step {ck_step}; data stream "
                  f"re-addressed for {len(alive)} hosts")

        # per-host step times: host 3 is a straggler
        times = {h: (2.2 if h == 3 else 1.0) for h in alive}
        strag.record(times)
        slow = strag.stragglers()
        if slow and step % 5 == 0:
            w = strag.batch_weights()
            split = rebalanced_batch_split(
                dc.global_batch, [w[h] for h in alive])
            print(f"step {step}: stragglers {slow}; batch re-split "
                  f"{dict(zip(alive, split))}")

        # one real training step on the (simulated) fleet's global batch
        batch = {k: jnp.asarray(v)
                 for k, v in stream.global_batch(step).items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if step % 5 == 0 or dead:
            print(f"step {step:3d} loss {float(loss):.4f}")
        if (step + 1) % 6 == 0:
            store.save(step + 1, (params, opt_state), blocking=False)
    store.wait()
    print("done — survived a failure and a straggler without losing the "
          "trajectory")


if __name__ == "__main__":
    main()
