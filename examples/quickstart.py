"""Quickstart: plan and run a paper-zoo CNN through the Table-3 ladder.

    PYTHONPATH=src python examples/quickstart.py [model] [image]

Builds ResNet-18 (default) as a graph, runs NeoCPU's optimization ladder
(NCHW baseline -> blocked layout -> transform elimination -> global
search -> operation fusion) as composable pass pipelines
(``Pipeline.preset(mode)`` — see docs/api.md for the pass/preset/session
API), verifies every level produces identical outputs, and prints the
planner's predicted v5e latency ladder plus host wall-clock and the
per-pass timing report.

For the full compile -> predict -> save -> load lifecycle (persistent
artifacts, per-batch specialization) see ``examples/serve_planned_cnn.py``
and ``repro.engine.compile``; for heavy-traffic serving on top of a saved
artifact (async driver, dynamic batching into the artifact's specialized
batch sizes, deterministic padded execution) see the "Serving" section of
docs/api.md and ``repro.engine.AsyncServer``.  Multi-core hosts can
replica-shard every specialization over the batch axis with
``compile(..., devices=n)`` (after
``repro.launch.cpu.configure_cpu_devices(n)``) or serve through
``AsyncServer(workers=n)`` replicas — docs/api.md "Multi-core execution".
For traffic-aware serving — measured arrival histograms, the learned
bucket-set solver behind ``save(buckets="auto")``, priority classes with
EDF packing, and multi-tenant ``FleetServer`` hosting — see docs/api.md
"Traffic-aware serving" and the replay benchmark
``benchmarks/serving_trace.py`` (``--smoke`` runs the CI gates locally).
The same front door also compiles LM decoders:
``compile(<LMConfig or ARCHS name>, (batch, max_len))`` returns an
``LMSession`` with seq-bucketed prefill, streamed greedy decode through
``AsyncServer.submit_stream``, and zero-search artifact reload — see
docs/api.md "LM serving" and ``benchmarks/lm_serving.py``.
"""
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core.pipeline import MODES, Pipeline     # noqa: E402
from repro.engine import compile_model              # noqa: E402
from repro.models.cnn import build                  # noqa: E402
from repro.nn.init import init_params               # noqa: E402


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "resnet-18"
    image = int(sys.argv[2]) if len(sys.argv) > 2 else 128
    print(f"== {name} @ {image}x{image}, batch 1 ==")

    graph, shapes = build(name, batch=1, image=image)
    params = init_params(graph, shapes, seed=0)
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=shapes["data"]).astype(np.float32))

    ref = None
    for mode in MODES:
        p = Pipeline.preset(mode).run(graph, shapes)
        m = compile_model(p, params)
        out = jax.block_until_ready(m.predict(x))     # compile + run
        t0 = time.perf_counter()
        for _ in range(3):
            out = jax.block_until_ready(m.predict(x))
        wall = (time.perf_counter() - t0) / 3
        if ref is None:
            ref = out
        err = float(jnp.abs(out - ref).max())
        solver = p.solution.method if p.solution else "-"
        passes = " ".join(f"{pr.name}={pr.seconds * 1e3:.0f}ms"
                          for pr in p.report.passes)
        print(f"{mode:15s} pred_v5e={p.predicted_total_s * 1e3:7.3f} ms  "
              f"wall_cpu={wall * 1e3:8.1f} ms  "
              f"transforms={p.planned.n_transforms:3d}  solver={solver:10s} "
              f"max|Δ|={err:.1e}")
        print(f"{'':15s} passes: {passes}")
        assert err < 1e-4, "planned graph must be semantics-preserving"
    print("all modes numerically identical — planning is free of "
          "semantic drift")


if __name__ == "__main__":
    main()
