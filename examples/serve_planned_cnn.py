"""End-to-end serving driver (the paper's workload: latency-focused CNN
inference, batch size 1, many requests).

    PYTHONPATH=src python examples/serve_planned_cnn.py [model] [n_requests]

Plans the model once (global search), binds weights (compile-time layout
transformation), then serves a stream of single-image requests and reports
the latency distribution — the experiment behind the paper's Table 2.
"""
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core.planner import plan                  # noqa: E402
from repro.engine import compile_model               # noqa: E402
from repro.models.cnn import build                   # noqa: E402
from repro.nn.init import init_params                # noqa: E402


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "resnet-18"
    n_req = int(sys.argv[2]) if len(sys.argv) > 2 else 20
    image = 128

    graph, shapes = build(name, batch=1, image=image)
    params = init_params(graph, shapes, seed=0)
    t0 = time.perf_counter()
    p = plan(graph, shapes, mode="global-search")
    t_plan = time.perf_counter() - t0
    model = compile_model(p, params)

    rng = np.random.default_rng(0)
    lat = []
    for i in range(n_req):
        x = jnp.asarray(rng.normal(size=shapes["data"]).astype(np.float32))
        t0 = time.perf_counter()
        out = jax.block_until_ready(model.predict(x))
        lat.append(time.perf_counter() - t0)
    lat_ms = np.asarray(lat[1:]) * 1e3    # drop compile-carrying first call
    print(f"model={name} plan_time={t_plan:.1f}s "
          f"(one-time; schedule DB caches workloads)")
    print(f"served {n_req} requests: p50={np.percentile(lat_ms, 50):.1f} "
          f"p90={np.percentile(lat_ms, 90):.1f} "
          f"p99={np.percentile(lat_ms, 99):.1f} ms")
    print(f"top-1 of last request: {int(jnp.argmax(out))}")


if __name__ == "__main__":
    main()
