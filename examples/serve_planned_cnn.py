"""End-to-end serving driver (the paper's workload: latency-focused CNN
inference, batch size 1, many requests) — now through the persistent
``InferenceSession`` lifecycle.

    PYTHONPATH=src python examples/serve_planned_cnn.py [model] [n_requests]

Compiles the model once (``engine.compile`` runs the full fusion+layout
pipeline and binds weights into their physical layouts), saves the
versioned artifact, then — as a cold-start server would — **loads the
artifact back** and serves a stream of single-image requests from the
loaded session, reporting the latency distribution.  The load path runs
zero schedule search and zero weight transformation: the Table-2
experiment, minus the per-process planning cost.  See docs/api.md.
"""
import sys
import tempfile
import time

import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.engine import compile as compile_session  # noqa: E402
from repro.launch.serve import serve_artifact        # noqa: E402


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "resnet-18"
    n_req = int(sys.argv[2]) if len(sys.argv) > 2 else 20
    image = 128

    t0 = time.perf_counter()
    session = compile_session(name, (1, 3, image, image))
    t_compile = time.perf_counter() - t0

    with tempfile.TemporaryDirectory(prefix="neocpu_session_") as artifact:
        session.save(artifact)
        print(f"model={name} compile_time={t_compile:.1f}s -> artifact "
              f"{artifact}")
        # cold-start server: load the artifact (zero search, zero
        # re-binding — serve_artifact asserts it) and serve the stream
        out = serve_artifact(artifact, n_req)
    print(f"top-1 of last request: {int(jnp.argmax(out))}")


if __name__ == "__main__":
    main()
