"""End-to-end serving driver (the paper's workload: latency-focused CNN
inference, many requests) — through the persistent ``InferenceSession``
lifecycle and the async dynamic-batching driver.

    PYTHONPATH=src python examples/serve_planned_cnn.py [model] [n_requests]

Compiles the model once (``engine.compile`` runs the full fusion+layout
pipeline and binds weights into their physical layouts), specializes the
serving buckets {1, 8}, saves the versioned artifact, then — as a
cold-start server would — **loads the artifact back** and serves a stream
of single-image requests through ``AsyncServer``: bounded queue, dynamic
batching into the artifact's buckets, graceful drain.  The load path runs
zero schedule search and zero weight transformation, and the driver's
responses are bit-identical to serving the same artifact one request at a
time.  See docs/api.md ("Serving").
"""
import sys
import tempfile
import time

import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.engine import compile as compile_session  # noqa: E402
from repro.launch.serve import serve_artifact        # noqa: E402

SERVE_BUCKET = 8


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "resnet-18"
    n_req = int(sys.argv[2]) if len(sys.argv) > 2 else 20
    image = 128

    t0 = time.perf_counter()
    session = compile_session(name, (1, 3, image, image))
    session.specialize(SERVE_BUCKET)     # the bucket the driver packs into
    t_compile = time.perf_counter() - t0

    with tempfile.TemporaryDirectory(prefix="neocpu_session_") as artifact:
        session.save(artifact)
        print(f"model={name} compile_time={t_compile:.1f}s -> artifact "
              f"{artifact} (buckets {session.batch_sizes})")
        # cold-start server: load the artifact (zero search, zero
        # re-binding — serve_artifact asserts it) and serve the stream
        # through the async dynamic-batching driver
        out = serve_artifact(artifact, n_req, max_batch=SERVE_BUCKET,
                             max_wait_ms=2.0)
    print(f"top-1 of last request: {int(jnp.argmax(out))}")


if __name__ == "__main__":
    main()
